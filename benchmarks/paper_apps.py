"""Paper figures/tables from the simulator: Fig 6, Fig 7, Fig 8, Fig 9,
Table 3 — plus the mesh-scaling companion study (``bench_apps_sharded``)
that runs the same apps (BFS / PageRank / k-means) as *real* sharded
MergePlan programs on a forced host mesh instead of the trace simulator.

The companion study reports, per mesh size:

* correctness vs the single-device reference for both the all-eager plan
  and the deferred/overlapped commit schedule (BFS must match bitwise —
  MIN is a lattice join; PageRank/k-means to float tolerance);
* per-level wire vectors (``hlo_cost.analyze_hlo`` over the compiled
  superstep programs) for the eager superstep, the deferred non-commit
  superstep, and the K-cycle commit — and the amortized per-superstep
  top-level bytes, which must show the ~K-fold reduction the ``:defer``
  plan promises (``check_level_costs.py`` gates this).

Each simulator runner prints CSV rows and returns them as dicts; the
sharded study emits tagged ``@repro-bench`` records from its subprocess."""

from __future__ import annotations

import time

from benchmarks.simulator import MachineConfig, run_trace
from benchmarks.traces import APPS, kmeans

# Working-set sweep relative to the (scaled) LLC; the paper runs 25%-400%.
# 4.0 is included only with --full-size traces (simulation time).
FRACS = (0.25, 0.5, 1.0, 2.0)


def _run(mc: MachineConfig, app: str, version: str, frac: float,
         **kw) -> tuple[dict, dict]:
    builder, _ = APPS[app]
    trace, meta = builder(mc, version, frac, **kw)
    t0 = time.time()
    res = run_trace(mc, trace)
    res["wall_s"] = time.time() - t0
    return res, meta


def fig6_speedup(mc: MachineConfig, quick: bool = False) -> list[dict]:
    """Per-app speedup of DUP and CCache relative to FGL vs. working set."""
    rows = []
    fracs = (0.5, 2.0) if quick else FRACS
    for app, (_, versions) in APPS.items():
        for frac in fracs:
            base = None
            for version in versions:
                res, meta = _run(mc, app, version, frac)
                if version == "fgl":
                    base = res["cycles_max"]
                speedup = base / max(res["cycles_max"], 1)
                rows.append({
                    "figure": "fig6", "app": app, "version": version,
                    "llc_frac": frac, "cycles": res["cycles_max"],
                    "speedup_vs_fgl": round(speedup, 3),
                    "llc_miss": res["llc_miss"],
                    "invalidations": res["invalidations"],
                    "evict_merges": res["evict_merges"],
                    "flush_merges": res["flush_merges"],
                })
    return rows


def fig7_half_llc(mc: MachineConfig, quick: bool = False) -> list[dict]:
    """CCache with HALF the LLC vs. DUP with the full LLC, equal absolute
    working set (= the full-size LLC capacity)."""
    rows = []
    half = MachineConfig(scale=mc.scale * 2)
    for app in APPS:
        if quick and app not in ("kv_store", "bfs"):
            continue
        dup_version = "dup"
        res_d, _ = _run(mc, app, dup_version, 1.0)
        # same absolute working set on the halved machine = 2x its LLC
        res_c, _ = _run(half, app, "ccache", 2.0)
        rows.append({
            "figure": "fig7", "app": app,
            "dup_cycles_fullLLC": res_d["cycles_max"],
            "ccache_cycles_halfLLC": res_c["cycles_max"],
            "ccache_speedup_with_half_llc":
                round(res_d["cycles_max"] / max(res_c["cycles_max"], 1), 3),
        })
    return rows


def table3_memory(mc: MachineConfig) -> list[dict]:
    """Peak memory overhead of FGL/DUP normalized to CCache (analytic from
    the trace layouts)."""
    rows = []
    for app, (builder, versions) in APPS.items():
        foot = {}
        for version in versions:
            _, meta = builder(mc, version, 1.0)
            foot[version] = meta["footprint_lines"]
        base = foot["ccache"]
        rows.append({"figure": "table3", "app": app,
                     **{f"{v}_over_ccache": round(foot[v] / base, 2)
                        for v in foot}})
    return rows


def fig8_characterization(mc: MachineConfig, quick: bool = False
                          ) -> list[dict]:
    """Invalidations / LLC misses / directory accesses per 1k cycles."""
    rows = []
    fracs = (1.0,) if quick else (0.5, 2.0)
    for app, (_, versions) in APPS.items():
        for frac in fracs:
            for version in versions:
                res, _ = _run(mc, app, version, frac)
                kcyc = max(res["cycles_max"], 1) / 1000
                rows.append({
                    "figure": "fig8", "app": app, "version": version,
                    "llc_frac": frac,
                    "inval_per_kcyc": round(res["invalidations"] / kcyc, 3),
                    "llc_miss_per_kcyc": round(res["llc_miss"] / kcyc, 3),
                    "directory_per_kcyc": round(res["directory"] / kcyc, 3),
                })
    return rows


def fig9_merge_on_evict(mc: MachineConfig) -> list[dict]:
    """Merge-count reduction from merge-on-evict (vs. eager merging) and the
    dirty-merge silent-eviction count (PageRank's 24x fewer merges)."""
    rows = []
    # K-means: eager merges after every point vs. merge-on-evict.
    for version in ("ccache", "ccache_eager"):
        trace, _ = kmeans(mc, version, 1.0)
        res = run_trace(mc, trace)
        rows.append({"figure": "fig9", "app": "kmeans", "version": version,
                     "total_merges": res["evict_merges"] + res["flush_merges"],
                     "evict_merges": res["evict_merges"],
                     "flush_merges": res["flush_merges"],
                     "silent_evicts": res["silent_evicts"]})
    eager = rows[-1]["total_merges"]
    opt = rows[-2]["total_merges"]
    rows.append({"figure": "fig9", "app": "kmeans",
                 "version": "reduction",
                 "merge_reduction_x": round(eager / max(opt, 1), 1)})
    # PageRank dirty-merge: silent evictions = merges avoided on clean CData.
    res, _ = _run(mc, "pagerank", "ccache", 1.0)
    merges = res["evict_merges"] + res["flush_merges"]
    rows.append({"figure": "fig9", "app": "pagerank", "version": "ccache",
                 "total_merges": merges,
                 "silent_evicts": res["silent_evicts"],
                 "dirty_merge_reduction_x":
                     round((merges + res["silent_evicts"]) / max(merges, 1), 2)})
    return rows


# Deferred commit interval for the sharded apps study; matches the apps'
# acceptance runs and the kmeans commit schedule.
APPS_DEFER_K = 4


def bench_apps_sharded(quick: bool = False) -> list[dict]:
    """Mesh-scaling companion to fig 6: the apps as sharded MergePlan
    programs. Respawns in a forced-device subprocess (like hierarchy/lm_tier)
    so the parent keeps its single-device view; ``--quick`` runs the 8-shard
    mesh only, full adds 16 shards."""
    import os
    import subprocess
    import sys
    n_dev = 8 if quick else 16
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src"), os.path.abspath("."),
                    os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.paper_apps", "--sub-apps",
         "quick" if quick else "full"],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        return [{"bench": "apps_sharded", "error": out.stderr[-600:]}]
    from benchmarks.records import iter_records
    return list(iter_records(out.stdout.splitlines()))


def _apps_sub_main(quick: bool) -> None:
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import jax.numpy as jnp

    from benchmarks.records import emit_record
    from repro.apps import bfs_superstep, pagerank_superstep
    from repro.apps.common import default_plan
    from repro.apps.sharded import build_mesh, run_app
    from repro.core import ccache
    from repro.core.merge_functions import ADD, MIN
    from repro.launch import hlo_cost

    k = APPS_DEFER_K
    n_vertices = 24 if quick else 48
    n_edges = 96 if quick else 160
    alpha = 0.5
    base = (1.0 - alpha) / n_vertices

    for n_shards in ((8,) if quick else (8, 16)):
        # --- correctness on the real mesh, Pallas scatter phase ---
        for app in ("bfs", "pagerank", "kmeans"):
            rec = run_app(app, n_shards, defer_k=k, use_pallas=True,
                          n_vertices=n_vertices, n_edges=n_edges)
            emit_record({"bench": "apps_sharded",
                         "case": f"{app}_correctness_s{n_shards}", **rec})

        # --- per-level wire vectors of the compiled superstep programs ---
        axis = "shards"
        mesh = build_mesh(n_shards, axis)
        plan = default_plan(n_shards)
        plan_d = default_plan(n_shards, defer_top=True)
        sizes = tuple(lv.size for lv in plan.levels)
        names = tuple(lv.name for lv in plan.levels)
        group = 1
        for s in sizes[:-1]:
            group *= s

        dist_s = jax.ShapeDtypeStruct((n_shards, n_vertices), jnp.int32)
        rank_s = jax.ShapeDtypeStruct((n_shards, n_vertices), jnp.float32)
        e_per = -(-(n_edges + n_vertices) // n_shards)
        edge_s = jax.ShapeDtypeStruct((n_shards, e_per), jnp.int32)

        def _walk(fn, *args):
            def region(*locals_):
                loc = [jax.tree.map(lambda x: x[0], a) for a in locals_]
                out = fn(*loc)
                return jax.tree.map(lambda x: x[None], out)
            f = jax.jit(shard_map(region, mesh=mesh,
                                  in_specs=(P(axis),) * len(args),
                                  out_specs=P(axis), check_rep=False))
            hlo = f.lower(*args).compile().as_text()
            return hlo_cost.analyze_hlo(hlo, intra_group_size=group,
                                        level_sizes=sizes, level_names=names)

        def _emit(app, case, walk, extra=None):
            row = {"bench": "apps_sharded", "app": app,
                   "case": f"{app}_{case}_s{n_shards}", "n_shards": n_shards,
                   "level_names": list(names), "level_sizes": list(sizes),
                   "wire_bytes_by_level_total":
                       walk["wire_bytes_by_level_total"],
                   "collectives": {c: v["count"]
                                   for c, v in walk["per_collective"].items()}}
            row.update(extra or {})
            emit_record(row)
            return row

        def _amortized(app, eager_w, step_w, commit_w):
            """Per-superstep bytes of a K-cycle: K-1 non-commit steps + one
            commit step, vs the all-eager superstep's top level."""
            step_lv = step_w["wire_bytes_by_level_total"]
            commit_lv = commit_w["wire_bytes_by_level_total"]
            amort = [(s * (k - 1) + c) / k
                     for s, c in zip(step_lv, commit_lv)]
            eager_top = eager_w["wire_bytes_by_level_total"][-1]
            emit_record({
                "bench": "apps_sharded", "app": app,
                "case": f"{app}_defer_amortized_s{n_shards}",
                "n_shards": n_shards, "commit_every": k,
                "level_names": list(names),
                "wire_bytes_by_level_total": amort,
                "top_level_bytes_eager": eager_top,
                "top_level_bytes_amortized": amort[-1],
                "top_level_amortization_x": round(eager_top / amort[-1], 2)
                if amort[-1] else None})

        # BFS: eager superstep merges all levels; deferred superstep joins
        # the eager scope only; the commit settles the pod-scope pending.
        def bfs_eager(dist, src, dst):
            cand = bfs_superstep(dist, src, dst)
            return jnp.minimum(
                dist, ccache.hierarchical_merge(cand, axis, MIN, plan))

        def bfs_defer_step(dist, src, dst, pending):
            cand = bfs_superstep(dist, src, dst)
            u = ccache.partial_merge(cand, axis, MIN, plan_d)
            return jnp.minimum(dist, u), jnp.minimum(pending, u)

        def bfs_defer_commit(dist, src, dst, pending):
            cand = bfs_superstep(dist, src, dst)
            u = ccache.partial_merge(cand, axis, MIN, plan_d)
            settled = ccache.settle_deferred(
                jnp.minimum(pending, u), axis, MIN, plan_d)
            return (jnp.minimum(jnp.minimum(dist, u), settled),
                    jnp.full_like(pending, jnp.iinfo(jnp.int32).max))

        bw_e = _walk(bfs_eager, dist_s, edge_s, edge_s)
        bw_s = _walk(bfs_defer_step, dist_s, edge_s, edge_s, dist_s)
        bw_c = _walk(bfs_defer_commit, dist_s, edge_s, edge_s, dist_s)
        _emit("bfs", "eager_step", bw_e)
        _emit("bfs", "defer_step", bw_s)
        _emit("bfs", "defer_commit", bw_c, {"commit_every": k})
        _amortized("bfs", bw_e, bw_s, bw_c)

        # PageRank: same three programs over the ADD merge.
        def pr_eager(r, src, dst, deg):
            c = pagerank_superstep(r, src, dst, deg, alpha=alpha)
            return base + ccache.hierarchical_merge(c, axis, ADD, plan)

        def pr_defer_step(r, remote, src, dst, deg):
            c = pagerank_superstep(r, src, dst, deg, alpha=alpha)
            u = ccache.partial_merge(c, axis, ADD, plan_d)
            return base + u + remote, remote

        def pr_defer_commit(r, remote, src, dst, deg):
            c = pagerank_superstep(r, src, dst, deg, alpha=alpha)
            u = ccache.partial_merge(c, axis, ADD, plan_d)
            full = ccache.settle_deferred(u, axis, ADD, plan_d)
            return base + full, full - u

        pw_e = _walk(pr_eager, rank_s, edge_s, edge_s, rank_s)
        pw_s = _walk(pr_defer_step, rank_s, rank_s, edge_s, edge_s, rank_s)
        pw_c = _walk(pr_defer_commit, rank_s, rank_s, edge_s, edge_s, rank_s)
        _emit("pagerank", "eager_step", pw_e)
        _emit("pagerank", "defer_step", pw_s)
        _emit("pagerank", "defer_commit", pw_c, {"commit_every": k})
        _amortized("pagerank", pw_e, pw_s, pw_c)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sub-apps", choices=["quick", "full"])
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.sub_apps:
        _apps_sub_main(a.sub_apps == "quick")
    else:
        from benchmarks.records import emit_record
        for r in bench_apps_sharded(quick=a.quick):
            emit_record(r)
