"""Paper figures/tables from the simulator: Fig 6, Fig 7, Fig 8, Fig 9,
Table 3. Each runner prints CSV rows and returns them as dicts."""

from __future__ import annotations

import time

from benchmarks.simulator import MachineConfig, run_trace
from benchmarks.traces import APPS, kmeans

# Working-set sweep relative to the (scaled) LLC; the paper runs 25%-400%.
# 4.0 is included only with --full-size traces (simulation time).
FRACS = (0.25, 0.5, 1.0, 2.0)


def _run(mc: MachineConfig, app: str, version: str, frac: float,
         **kw) -> tuple[dict, dict]:
    builder, _ = APPS[app]
    trace, meta = builder(mc, version, frac, **kw)
    t0 = time.time()
    res = run_trace(mc, trace)
    res["wall_s"] = time.time() - t0
    return res, meta


def fig6_speedup(mc: MachineConfig, quick: bool = False) -> list[dict]:
    """Per-app speedup of DUP and CCache relative to FGL vs. working set."""
    rows = []
    fracs = (0.5, 2.0) if quick else FRACS
    for app, (_, versions) in APPS.items():
        for frac in fracs:
            base = None
            for version in versions:
                res, meta = _run(mc, app, version, frac)
                if version == "fgl":
                    base = res["cycles_max"]
                speedup = base / max(res["cycles_max"], 1)
                rows.append({
                    "figure": "fig6", "app": app, "version": version,
                    "llc_frac": frac, "cycles": res["cycles_max"],
                    "speedup_vs_fgl": round(speedup, 3),
                    "llc_miss": res["llc_miss"],
                    "invalidations": res["invalidations"],
                    "evict_merges": res["evict_merges"],
                    "flush_merges": res["flush_merges"],
                })
    return rows


def fig7_half_llc(mc: MachineConfig, quick: bool = False) -> list[dict]:
    """CCache with HALF the LLC vs. DUP with the full LLC, equal absolute
    working set (= the full-size LLC capacity)."""
    rows = []
    half = MachineConfig(scale=mc.scale * 2)
    for app in APPS:
        if quick and app not in ("kv_store", "bfs"):
            continue
        dup_version = "dup"
        res_d, _ = _run(mc, app, dup_version, 1.0)
        # same absolute working set on the halved machine = 2x its LLC
        res_c, _ = _run(half, app, "ccache", 2.0)
        rows.append({
            "figure": "fig7", "app": app,
            "dup_cycles_fullLLC": res_d["cycles_max"],
            "ccache_cycles_halfLLC": res_c["cycles_max"],
            "ccache_speedup_with_half_llc":
                round(res_d["cycles_max"] / max(res_c["cycles_max"], 1), 3),
        })
    return rows


def table3_memory(mc: MachineConfig) -> list[dict]:
    """Peak memory overhead of FGL/DUP normalized to CCache (analytic from
    the trace layouts)."""
    rows = []
    for app, (builder, versions) in APPS.items():
        foot = {}
        for version in versions:
            _, meta = builder(mc, version, 1.0)
            foot[version] = meta["footprint_lines"]
        base = foot["ccache"]
        rows.append({"figure": "table3", "app": app,
                     **{f"{v}_over_ccache": round(foot[v] / base, 2)
                        for v in foot}})
    return rows


def fig8_characterization(mc: MachineConfig, quick: bool = False
                          ) -> list[dict]:
    """Invalidations / LLC misses / directory accesses per 1k cycles."""
    rows = []
    fracs = (1.0,) if quick else (0.5, 2.0)
    for app, (_, versions) in APPS.items():
        for frac in fracs:
            for version in versions:
                res, _ = _run(mc, app, version, frac)
                kcyc = max(res["cycles_max"], 1) / 1000
                rows.append({
                    "figure": "fig8", "app": app, "version": version,
                    "llc_frac": frac,
                    "inval_per_kcyc": round(res["invalidations"] / kcyc, 3),
                    "llc_miss_per_kcyc": round(res["llc_miss"] / kcyc, 3),
                    "directory_per_kcyc": round(res["directory"] / kcyc, 3),
                })
    return rows


def fig9_merge_on_evict(mc: MachineConfig) -> list[dict]:
    """Merge-count reduction from merge-on-evict (vs. eager merging) and the
    dirty-merge silent-eviction count (PageRank's 24x fewer merges)."""
    rows = []
    # K-means: eager merges after every point vs. merge-on-evict.
    for version in ("ccache", "ccache_eager"):
        trace, _ = kmeans(mc, version, 1.0)
        res = run_trace(mc, trace)
        rows.append({"figure": "fig9", "app": "kmeans", "version": version,
                     "total_merges": res["evict_merges"] + res["flush_merges"],
                     "evict_merges": res["evict_merges"],
                     "flush_merges": res["flush_merges"],
                     "silent_evicts": res["silent_evicts"]})
    eager = rows[-1]["total_merges"]
    opt = rows[-2]["total_merges"]
    rows.append({"figure": "fig9", "app": "kmeans",
                 "version": "reduction",
                 "merge_reduction_x": round(eager / max(opt, 1), 1)})
    # PageRank dirty-merge: silent evictions = merges avoided on clean CData.
    res, _ = _run(mc, "pagerank", "ccache", 1.0)
    merges = res["evict_merges"] + res["flush_merges"]
    rows.append({"figure": "fig9", "app": "pagerank", "version": "ccache",
                 "total_merges": merges,
                 "silent_evicts": res["silent_evicts"],
                 "dirty_merge_reduction_x":
                     round((merges + res["silent_evicts"]) / max(merges, 1), 2)})
    return rows
