"""Tagged JSON record stream for the benchmark harness.

The CI gate (``scripts/ci.sh``) pipes ``benchmarks.run`` into
``scripts/check_level_costs.py``, and benchmark runners re-parse their
subprocesses' stdout. Bare ``print(json.dumps(...))`` rows made every one of
those consumers grep for lines starting with ``{`` — which any stray log
line (jax warnings, XLA dumps, a debugging print that happens to open a
brace) could break or poison. Records therefore carry an explicit tag:

    @repro-bench {"bench": "hierarchy", ...}

``emit_record`` writes one, ``parse_record``/``iter_records`` read them
back, and every non-record line is passed through untouched and ignored.
"""

from __future__ import annotations

import json
from typing import Iterable, Iterator, Optional

RECORD_TAG = "@repro-bench"


def emit_record(row: dict) -> None:
    print(f"{RECORD_TAG} {json.dumps(row)}", flush=True)


def parse_record(line: str) -> Optional[dict]:
    s = line.strip()
    if not s.startswith(RECORD_TAG):
        return None
    try:
        rec = json.loads(s[len(RECORD_TAG):])
    except json.JSONDecodeError:
        return None
    return rec if isinstance(rec, dict) else None


def iter_records(lines: Iterable[str]) -> Iterator[dict]:
    for line in lines:
        rec = parse_record(line)
        if rec is not None:
            yield rec


def record_key(rec: dict) -> Optional[tuple]:
    """The identity downstream consumers look a case record up by
    (``check_baseline.find`` takes the FIRST match; anything keyed the
    same is silently dead weight). ``None`` for summary/unkeyed records."""
    if "summary" in rec:
        return None
    bench, case = rec.get("bench"), rec.get("case")
    if bench is None and case is None:
        return None
    return (bench, case)


def duplicate_record_keys(records: Iterable[dict]) -> list[str]:
    """Silent last/first-write-wins collisions in one record stream.

    Two case records sharing a (bench, case) key, or a summary key emitted
    by more than one summary record, mean a consumer picks one value and
    drops the other without a trace — a renamed case or a double-emitting
    runner can un-gate a metric this way. Returns one line per collision,
    quoting BOTH values, for the caller to fail loudly with.
    """
    problems: list[str] = []
    first_case: dict = {}
    first_summary: dict = {}
    for rec in records:
        if "summary" in rec and isinstance(rec["summary"], dict):
            for k, v in rec["summary"].items():
                if k in first_summary:
                    problems.append(
                        f"summary key {k!r} emitted by two summary records: "
                        f"first={first_summary[k]!r} then={v!r}")
                else:
                    first_summary[k] = v
            continue
        key = record_key(rec)
        if key is None:
            continue
        if key in first_case:
            problems.append(
                f"duplicate record key bench={key[0]!r} case={key[1]!r}: "
                f"kept={json.dumps(first_case[key], sort_keys=True)} "
                f"shadowed={json.dumps(rec, sort_keys=True)}")
        else:
            first_case[key] = rec
    return problems
