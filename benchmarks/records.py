"""Tagged JSON record stream for the benchmark harness.

The CI gate (``scripts/ci.sh``) pipes ``benchmarks.run`` into
``scripts/check_level_costs.py``, and benchmark runners re-parse their
subprocesses' stdout. Bare ``print(json.dumps(...))`` rows made every one of
those consumers grep for lines starting with ``{`` — which any stray log
line (jax warnings, XLA dumps, a debugging print that happens to open a
brace) could break or poison. Records therefore carry an explicit tag:

    @repro-bench {"bench": "hierarchy", ...}

``emit_record`` writes one, ``parse_record``/``iter_records`` read them
back, and every non-record line is passed through untouched and ignored.
"""

from __future__ import annotations

import json
from typing import Iterable, Iterator, Optional

RECORD_TAG = "@repro-bench"


def emit_record(row: dict) -> None:
    print(f"{RECORD_TAG} {json.dumps(row)}", flush=True)


def parse_record(line: str) -> Optional[dict]:
    s = line.strip()
    if not s.startswith(RECORD_TAG):
        return None
    try:
        rec = json.loads(s[len(RECORD_TAG):])
    except json.JSONDecodeError:
        return None
    return rec if isinstance(rec, dict) else None


def iter_records(lines: Iterable[str]) -> Iterator[dict]:
    for line in lines:
        rec = parse_record(line)
        if rec is not None:
            yield rec
