"""LM-scale CCache benchmarks: flexible merge collectives + cscatter.

Collective-byte measurements need >1 device, so those benches respawn
themselves in a subprocess with 8 forced host devices (the main process
keeps the container's single-device view, per the brief).

CSV metrics:
  merge_path      wire bytes + wall time of psum (COUP fast path) vs the
                  ppermute butterfly (CCache flexible path) vs int8-compressed
  grad_accum      collectives per train step at 1 vs 8 microbatches
                  (soft-merge: deferral keeps it at one merge per step)
  cscatter        wall us of the privatized scatter vs XLA scatter-add
                  (interpret mode: structural check, not TPU timing)
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

from benchmarks.records import emit_record, iter_records


def _sub(mode: str) -> list[dict]:
    """Run a sub-benchmark in a subprocess with 8 forced host devices."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src"), os.path.abspath("."),
                    os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.lm_tier", "--sub", mode],
        env=env, capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        return [{"bench": mode, "error": out.stderr[-400:]}]
    return list(iter_records(out.stdout.splitlines()))


def bench_merge_paths() -> list[dict]:
    return _sub("merges")


def bench_grad_accum() -> list[dict]:
    return _sub("accum")


def bench_cscatter() -> list[dict]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    rows = []
    key = jax.random.key(0)
    for rows_n, d, n in ((4096, 128, 8192), (16384, 256, 16384)):
        table = jax.random.normal(key, (rows_n, d), jnp.float32)
        ids = jax.random.randint(jax.random.key(1), (n,), 0, rows_n)
        vals = jax.random.normal(jax.random.key(2), (n, d), jnp.float32)

        def timed(f, *a):
            r = f(*a)
            jax.block_until_ready(r)
            t0 = time.time()
            for _ in range(3):
                r = f(*a)
            jax.block_until_ready(r)
            return (time.time() - t0) / 3 * 1e6

        t_kernel = timed(lambda: ops.commutative_scatter(
            table, ids, vals, kind="add", block_rows=512, chunk=1024))
        xla = jax.jit(lambda t, i, v: t.at[i].add(v))
        t_xla = timed(xla, table, ids, vals)
        rows.append({"bench": "cscatter", "table": f"{rows_n}x{d}",
                     "updates": n,
                     "kernel_interpret_us": round(t_kernel, 1),
                     "xla_scatter_us": round(t_xla, 1),
                     "note": "interpret-mode timing is structural only"})
    return rows


# ---------------------------------------------------------------------------
# subprocess entry points (8 forced devices)
# ---------------------------------------------------------------------------


def _merges_main() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.core import ccache, merge_functions as mf
    from repro.launch import hlo_cost

    mesh = jax.make_mesh((8,), ("data",))
    n = 1 << 20  # 4 MB f32 per device
    x = jnp.arange(8 * n, dtype=jnp.float32).reshape(8, n) / n

    cases = {
        "psum_fastpath": lambda u: ccache.reduce_update(u, "data", mf.ADD),
        "tree_flexible": lambda u: ccache.reduce_update(
            u, "data", mf.ADD, force_tree=True),
        "tree_int8_compressed": lambda u: ccache.reduce_update(
            u, "data", mf.int8_compressed_add(), compress=True),
        "tree_saturating": lambda u: ccache.reduce_update(
            u, "data", mf.saturating_add(1e9), force_tree=True),
    }
    for name, fn in cases.items():
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data")))
        lowered = f.lower(jax.ShapeDtypeStruct((8, n), jnp.float32))
        compiled = lowered.compile()
        walk = hlo_cost.analyze_hlo(compiled.as_text())
        r = f(x)
        jax.block_until_ready(r)
        t0 = time.time()
        for _ in range(5):
            r = f(x)
        jax.block_until_ready(r)
        wall = (time.time() - t0) / 5 * 1e6
        emit_record({
            "bench": "merge_path", "case": name,
            "wire_bytes_per_device": walk["wire_bytes"],
            "collectives": {k: v["count"]
                            for k, v in walk["per_collective"].items()},
            "wall_us_8cpudev": round(wall, 1)})


def _accum_main() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.grad_merge import microbatched_value_and_grad
    from repro.launch import hlo_cost

    mesh = jax.make_mesh((8,), ("data",))
    d = 512

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((h - batch["y"]) ** 2)

    params = {"w1": jax.ShapeDtypeStruct((d, d), jnp.float32),
              "w2": jax.ShapeDtypeStruct((d, d), jnp.float32)}
    batch = {"x": jax.ShapeDtypeStruct((64, d), jnp.float32),
             "y": jax.ShapeDtypeStruct((64, d), jnp.float32)}
    repl = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P("data"))

    for n_micro in (1, 8):
        if n_micro == 1:
            step = jax.value_and_grad(loss_fn)
        else:
            step = microbatched_value_and_grad(loss_fn, n_micro)
        f = jax.jit(step, in_shardings=(
            {"w1": repl, "w2": repl},
            {"x": shard, "y": shard}))
        compiled = f.lower(params, batch).compile()
        walk = hlo_cost.analyze_hlo(compiled.as_text())
        emit_record({
            "bench": "grad_accum", "microbatches": n_micro,
            "wire_bytes_per_device": walk["wire_bytes"],
            "collectives": {k: v["count"]
                            for k, v in walk["per_collective"].items()},
            "note": "soft-merge defers: one cross-device merge per step"})


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sub", choices=["merges", "accum"], required=True)
    a = ap.parse_args()
    if a.sub == "merges":
        _merges_main()
    else:
        _accum_main()
