"""Flat vs hierarchical merge on the 2-pod mesh: wire bytes + simulated time.

Compiles (never executes — the collectives are what we're costing) each merge
strategy under ``shard_map`` over a flattened data-parallel axis shaped like
the production pod mesh, then walks the partitioned HLO with
``hlo_cost.analyze_hlo(intra_group_size=pod)`` to split collective bytes into
intra-pod (ICI) and inter-pod (DCI) levels. Simulated time charges each level
at its bandwidth:

    t = intra_total / (chips * ICI_BW)  +  inter_total / DCI_TOTAL

where DCI_TOTAL is the shared inter-pod pipe. The paper-level claim under
test: the hierarchical engine's representative-only inter-group exchange
cuts inter-pod bytes by the group-size factor vs the flat butterfly.

Device counts: full = pod2x16x16 (512 forced host devices, group 256);
``--quick`` = pod2x4x4 (32 devices, group 16). Like lm_tier, the multi-device
part respawns in a subprocess so the parent keeps its single-device view.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

# Modeled hardware (mirrors repro.launch.hlo_analysis; DCI_TOTAL is the
# aggregate inter-pod pipe rather than a per-chip share).
ICI_BW = 50e9
DCI_TOTAL = 800e9


def bench_hierarchy(quick: bool = False) -> list[dict]:
    """Run the flat-vs-hierarchical comparison in a forced-device subprocess."""
    n_dev = 32 if quick else 512
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src"), os.path.abspath("."),
                    os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.hierarchy", "--sub",
         "quick" if quick else "full"],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        return [{"bench": "hierarchy", "error": out.stderr[-600:]}]
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("{"):
            rows.append(json.loads(line))
    return rows


def _sub_main(quick: bool) -> None:
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import ccache
    from repro.core import merge_functions as mf
    from repro.launch import hlo_cost

    # pod2x4x4 (quick) or pod2x16x16: the dp axis flattens (pod, data, model)
    # rank-major, so one pod = the first `group` ranks — aligned groups.
    chips = 32 if quick else 512
    group = chips // 2
    mesh_name = "pod2x4x4" if quick else "pod2x16x16"
    mesh = jax.make_mesh((chips,), ("dp",))
    n = (1 << 16) if quick else (1 << 20)  # per-device f32 update elements
    sds = jax.ShapeDtypeStruct((chips, n), jnp.float32)
    topo = ccache.MergeTopology(group_size=group)

    cases = {
        "flat_butterfly": lambda u: ccache.tree_merge(u, "dp", mf.ADD),
        "hierarchical": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, topo),
        "hierarchical_softpath": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, topo, force_tree=True),
        "hierarchical_int8_inter": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.int8_compressed_add(), topo, compress=True),
        "psum_fastpath": lambda u: ccache.reduce_update(u, "dp", mf.ADD),
    }
    for name, fn in cases.items():
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("dp"),
                              out_specs=P("dp"), check_rep=False))
        hlo = f.lower(sds).compile().as_text()
        walk = hlo_cost.analyze_hlo(hlo, intra_group_size=group)
        intra = walk["wire_bytes_intra_total"]
        inter = walk["wire_bytes_inter_total"]
        sim_s = intra / (chips * ICI_BW) + inter / DCI_TOTAL
        print(json.dumps({
            "bench": "hierarchy", "mesh": mesh_name, "chips": chips,
            "group_size": group, "case": name,
            "update_mb_per_device": round(n * 4 / 1e6, 2),
            "wire_bytes_per_device": walk["wire_bytes"],
            "wire_bytes_intra_total": intra,
            "wire_bytes_inter_total": inter,
            "sim_time_us": round(sim_s * 1e6, 2),
            "collectives": {k: v["count"]
                            for k, v in walk["per_collective"].items()}}))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sub", choices=["quick", "full"])
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.sub:
        _sub_main(a.sub == "quick")
    else:
        for r in bench_hierarchy(quick=a.quick):
            print(json.dumps(r))
