"""Flat vs hierarchical merge on the 2-pod mesh: wire bytes + simulated time.

Compiles (never executes — the collectives are what we're costing) each merge
strategy under ``shard_map`` over a flattened data-parallel axis shaped like
the production pod mesh, then walks the partitioned HLO with
``hlo_cost.analyze_hlo(level_sizes=...)`` to split collective bytes into the
per-level hierarchy vector (chip / host / pod). Simulated time charges each
level at its bandwidth:

    t = chip / (chips * ICI_BW) + host / (chips * ICI_BW/2) + pod / DCI_TOTAL

where DCI_TOTAL is the shared inter-pod pipe. Claims under test:

* two-level (PR-1): the representative-only inter-group exchange cuts
  inter-pod bytes by the group-size factor vs the flat butterfly;
* three-level MergePlan (chip:16,host:16,pod:2 on the full mesh): the same
  per-level, with the top level ≥100x cheaper than the flat butterfly's,
  and the lane-parallel exchange moving identical bytes over stride-times
  more links;
* merge-on-evict: a plan with ``pod:...:defer`` pays the pod level once per
  K-step commit — the per-step amortized top-level bytes drop ~K-fold
  (paper's mergeable bit, level 2);
* overlapped commits (hier3_overlap): the launch/land pipeline puts the
  top-level commit exchange in the same program as the next step's compute
  (no data dependency), hiding >= 50% of its measured time behind a
  compute-bound step — and the overlap-aware solver picks K no larger than
  the serialized solver's.

Device counts: full = pod2x16x16 (512 forced host devices, chip:16,host:16,
pod:2); ``--quick`` = pod2x4x4 (32 devices, chip:4,host:4,pod:2). Like
lm_tier, the multi-device part respawns in a subprocess so the parent keeps
its single-device view.
"""

from __future__ import annotations

import os
import subprocess
import sys

# Modeled hardware (mirrors repro.launch.hlo_analysis; DCI_TOTAL is the
# aggregate inter-pod pipe rather than a per-chip share). DCI_CONGESTED is
# the oversubscribed pipe the auto-defer canary solves against — the regime
# where deferring the top level matters.
ICI_BW = 50e9
HOST_BW = 25e9
DCI_TOTAL = 800e9
DCI_CONGESTED = DCI_TOTAL / 128
DEFER_K = 8
PEAK_FLOPS = 197e12  # per-chip bf16 rate (mirrors hlo_analysis.PEAK_FLOPS)


def bench_hierarchy(quick: bool = False) -> list[dict]:
    """Run the flat-vs-hierarchical comparison in a forced-device subprocess."""
    n_dev = 32 if quick else 512
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src"), os.path.abspath("."),
                    os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.hierarchy", "--sub",
         "quick" if quick else "full"],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        return [{"bench": "hierarchy", "error": out.stderr[-600:]}]
    from benchmarks.records import iter_records
    return list(iter_records(out.stdout.splitlines()))


def _sim_time_s(by_level_total: list[float], chips: int) -> float:
    bws = [chips * ICI_BW, chips * HOST_BW, DCI_TOTAL]
    return sum(b / bw for b, bw in zip(by_level_total, bws))


def _sub_main(quick: bool) -> None:
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from benchmarks.records import emit_record
    from repro.core import ccache
    from repro.core import merge_functions as mf
    from repro.core.defer_schedule import solve_defer_schedule
    from repro.core.merge_plan import MergePlan
    from repro.launch import hlo_cost

    # pod2x4x4 (quick) or pod2x16x16: the dp axis flattens (pod, data, model)
    # rank-major, so one pod = the first `group` ranks — aligned groups, and
    # the 3-level plan nests chip blocks inside host blocks inside pods.
    chips = 32 if quick else 512
    group = chips // 2
    chip = 4 if quick else 16
    host = group // chip
    mesh_name = "pod2x4x4" if quick else "pod2x16x16"
    level_sizes = (chip, host, 2)
    level_names = ("chip", "host", "pod")
    mesh = jax.make_mesh((chips,), ("dp",))
    n = (1 << 16) if quick else (1 << 20)  # per-device f32 update elements
    sds = jax.ShapeDtypeStruct((chips, n), jnp.float32)
    topo = ccache.MergeTopology(group_size=group)
    spec3 = f"chip:{chip},host:{host},pod:2"
    plan3 = MergePlan.parse(spec3)
    plan3_lane = MergePlan.parse(spec3, lane_parallel=True)
    plan3_defer = MergePlan.parse(spec3.replace("pod:2", "pod:2:defer"),
                                  lane_parallel=True)

    def _walk(fn, in_specs=P("dp"), args=(sds,)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=P("dp"), check_rep=False))
        hlo = f.lower(*args).compile().as_text()
        return hlo_cost.analyze_hlo(hlo, intra_group_size=group,
                                    level_sizes=level_sizes,
                                    level_names=level_names)

    def _emit(case: str, walk: dict, extra: dict | None = None) -> dict:
        by_level = walk["wire_bytes_by_level_total"]
        row = {
            "bench": "hierarchy", "mesh": mesh_name, "chips": chips,
            "group_size": group, "case": case,
            "level_names": list(level_names),
            "level_sizes": list(level_sizes),
            "update_mb_per_device": round(n * 4 / 1e6, 2),
            "wire_bytes_per_device": walk["wire_bytes"],
            "wire_bytes_by_level_total": by_level,
            "wire_bytes_intra_total": walk["wire_bytes_intra_total"],
            "wire_bytes_inter_total": walk["wire_bytes_inter_total"],
            "sim_time_us": round(_sim_time_s(by_level, chips) * 1e6, 2),
            "collectives": {k: v["count"]
                            for k, v in walk["per_collective"].items()}}
        row.update(extra or {})
        emit_record(row)
        return row

    cases = {
        "flat_butterfly": lambda u: ccache.tree_merge(u, "dp", mf.ADD),
        "hierarchical": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, topo),
        "hierarchical_softpath": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, topo, force_tree=True),
        "hierarchical_int8_inter": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.int8_compressed_add(), topo, compress=True),
        "hier3_rep": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, plan3),
        "hier3_lane": lambda u: ccache.hierarchical_merge(
            u, "dp", mf.ADD, plan3_lane),
        "psum_fastpath": lambda u: ccache.reduce_update(u, "dp", mf.ADD),
    }
    rows = {}
    for name, fn in cases.items():
        rows[name] = _emit(name, _walk(fn))

    # Merge-on-evict at pod scope: the per-step eager levels (chip+host)
    # vs the deferred pod-level commit paid once every K steps.
    step_walk = _walk(lambda u: ccache.partial_merge(u, "dp", mf.ADD,
                                                     plan3_defer))
    commit_walk = _walk(
        lambda u, m: ccache.commit_deferred(
            ccache.PendingUpdate(update=u), m, "dp", mf.ADD, plan3_defer),
        in_specs=(P("dp"), P("dp")), args=(sds, sds))
    rows["hier3_defer_step"] = _emit("hier3_defer_step", step_walk)
    rows["hier3_defer_commit"] = _emit("hier3_defer_commit", commit_walk)
    step_lv = step_walk["wire_bytes_by_level_total"]
    commit_lv = commit_walk["wire_bytes_by_level_total"]
    amortized = [s + c / DEFER_K for s, c in zip(step_lv, commit_lv)]
    eager_top = rows["hier3_lane"]["wire_bytes_by_level_total"][-1]
    emit_record({
        "bench": "hierarchy", "mesh": mesh_name, "chips": chips,
        "case": "hier3_defer_amortized", "commit_every": DEFER_K,
        "level_names": list(level_names),
        "wire_bytes_by_level_total": amortized,
        "sim_time_us": round(_sim_time_s(amortized, chips) * 1e6, 2),
        "top_level_bytes_eager": eager_top,
        "top_level_bytes_amortized": amortized[-1],
        "top_level_amortization_x": round(
            eager_top / amortized[-1], 2) if amortized[-1] else None})

    # Schedule-aware defer: the roofline solver picks K from the measured
    # eager per-level vector under a DCI oversubscribed vs the benchmark's
    # aggregate pipe (the regime where merge-on-evict matters), and the
    # measured amortization at that K must realize the prediction — the CI
    # canary for the solver + engine + classifier pipeline.
    lane_lv = rows["hier3_lane"]["wire_bytes_by_level_total"]
    schedule = solve_defer_schedule(
        plan3_defer, lane_lv, level_names,
        bandwidths=[chips * ICI_BW, chips * HOST_BW, DCI_CONGESTED])
    k_auto = schedule.intervals[-1]
    amort_auto = [s + c / k_auto for s, c in zip(step_lv, commit_lv)]
    predicted_top = schedule.predicted["per_level"][-1][
        "amortized_bytes_per_step"]
    emit_record({
        "bench": "hierarchy", "mesh": mesh_name, "chips": chips,
        "case": "hier3_defer_auto", "commit_every": k_auto,
        "schedule": schedule.as_dict(),
        "level_names": list(level_names),
        "wire_bytes_by_level_total": amort_auto,
        "sim_time_us": round(_sim_time_s(amort_auto, chips) * 1e6, 2),
        "top_level_bytes_eager": lane_lv[-1],
        "top_level_bytes_predicted": predicted_top,
        "top_level_bytes_measured": amort_auto[-1],
        "predicted_amortization_x": round(lane_lv[-1] / predicted_top, 2)
        if predicted_top else None,
        "top_level_amortization_x": round(lane_lv[-1] / amort_auto[-1], 2)
        if amort_auto[-1] else None})

    # Overlapped deferred commits (launch/land): the land-step program
    # carries the launched cycle's top-level exchange NEXT TO the next
    # step's compute, with no data dependency between them — so the
    # scheduler can hide the exchange behind the compute. Both sides are
    # measured from one compiled program's HLO (wire bytes for the
    # exchange, dot flops for the compute) and charged at the modeled
    # rates; the hidden fraction is what the overlap saves per commit
    # versus the serialized ``:defer`` commit. The matmul chain stands in
    # for a training step's fwd/bwd, sized to ~2/3 of the top-level
    # exchange time: the overlap hides most (but not all) of the commit,
    # and the overlap-aware solver — which only amortizes the exposed
    # remainder — picks a smaller K than the serialized solver at the
    # same compute bound.
    mm, chain = (1024, 5) if quick else (3072, 3)
    wsds = jax.ShapeDtypeStruct((chips, mm, mm), jnp.float32)

    def overlap_land(u, w):
        y = w[0]
        for _ in range(chain):
            y = y @ y
        settled = ccache.settle_inflight(u, "dp", mf.ADD, plan3_defer)
        return settled, y[None]

    f = jax.jit(shard_map(overlap_land, mesh=mesh,
                          in_specs=(P("dp"), P("dp")),
                          out_specs=(P("dp"), P("dp")), check_rep=False))
    ovl_hlo = f.lower(sds, wsds).compile().as_text()
    ovl_walk = hlo_cost.analyze_hlo(ovl_hlo, intra_group_size=group,
                                    level_sizes=level_sizes,
                                    level_names=level_names)
    t_top_s = ovl_walk["wire_bytes_by_level_total"][-1] / DCI_CONGESTED
    t_comp_s = ovl_walk["flops"] / PEAK_FLOPS
    hidden_s = min(t_top_s, t_comp_s)
    exposed_s = t_top_s - hidden_s
    # Apples-to-apples solver comparison at this step's compute bound:
    # overlap amortizes only the exposed remainder, so its K is never
    # larger (and usually smaller — committing more often is free while
    # the exchange stays behind the compute).
    bws = [chips * ICI_BW, chips * HOST_BW, DCI_CONGESTED]
    sched_serial = solve_defer_schedule(plan3_defer, lane_lv, level_names,
                                        bandwidths=bws, compute_s=t_comp_s)
    sched_ovl = solve_defer_schedule(plan3_defer, lane_lv, level_names,
                                     bandwidths=bws, compute_s=t_comp_s,
                                     overlap=True)
    emit_record({
        "bench": "hierarchy", "mesh": mesh_name, "chips": chips,
        "case": "hier3_overlap",
        "level_names": list(level_names),
        "wire_bytes_by_level_total": ovl_walk["wire_bytes_by_level_total"],
        "top_exchange_bytes": ovl_walk["wire_bytes_by_level_total"][-1],
        "top_exchange_time_us": round(t_top_s * 1e6, 2),
        "overlap_compute_time_us": round(t_comp_s * 1e6, 2),
        "exposed_time_us": round(exposed_s * 1e6, 2),
        "hidden_frac": round(hidden_s / t_top_s, 4) if t_top_s else None,
        "k_serialized": sched_serial.intervals[-1],
        "k_overlap": sched_ovl.intervals[-1],
        "collectives": {k: v["count"]
                        for k, v in ovl_walk["per_collective"].items()}})


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sub", choices=["quick", "full"])
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.sub:
        _sub_main(a.sub == "quick")
    else:
        from benchmarks.records import emit_record
        for r in bench_hierarchy(quick=a.quick):
            emit_record(r)
