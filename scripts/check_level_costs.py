#!/usr/bin/env python
"""CI guard over the hierarchy dryrun's per-level wire-byte vectors.

Reads the benchmark record stream on stdin (passed through unchanged),
collects the tagged ``@repro-bench {...}`` JSON records (``benchmarks/
records.py`` — anything else, including stray jax/XLA log lines, is
ignored), finds the 3-level hierarchy rows, and asserts the cost-model
invariants the MergePlan engine is built on:

1. monotonicity — the hierarchical merge puts monotonically more bytes on
   monotonically cheaper levels (chip >= host >= pod);
2. top-level reduction — the pod level carries at least group/2 fewer bytes
   than the flat butterfly's (the representative/lane exchange working);
3. defer amortization — the merge-on-evict commit amortizes top-level
   traffic by at least half the commit interval;
4. defer schedule — the roofline-solved commit interval (hier3_defer_auto)
   is a real deferral (K >= 2 under the congested-DCI scenario) and the
   measured top-level amortization realizes >= 80% of the predicted ~K-fold;
5. overlapped commits — the launch/land pipeline (hier3_overlap) hides at
   least 50% of the measured top-level exchange time behind the step's
   compute, and the overlap-aware solver's K is no larger than the
   serialized solver's at the same compute bound.

When the stream also carries ``apps_sharded`` records (the mesh-scaling
companion study: BFS / PageRank / k-means as sharded MergePlan programs),
the apps invariants are enforced too:

6. apps correctness — BFS matches the single-device reference bitwise on
   both the eager and the deferred plan (MIN is a lattice join); PageRank
   and k-means match to float tolerance;
7. apps defer amortization — the deferred supersteps amortize top-level
   wire bytes by at least K/2 vs the all-eager superstep (PageRank's
   deferred commit cycle must actually skip the cross-pod exchange).

When the stream carries ``kv_gups`` records (the serving tier,
``benchmarks/kv_gups.py``), the serving invariants are enforced too:

8. kv correctness + throughput — the privatized-deferred store matches
   the fully-synchronized reference bitwise after flush, AND ingests at
   >= 2x the reference's GUPS on the Pareto-skewed trace;
9. kv wire — a non-commit tick of the fully deferred plan moves zero
   collective bytes, and the K-cycle amortized top-level bytes undercut
   the sync tick's by >= K/2.

When the stream carries ``kv_part_*`` records (the partitioned serving
tier: home-sharded settled rows, spill-through-eviction pendings,
launch/land overlapped commits), the partitioning invariants are
enforced too:

10. partitioned correctness + throughput — the partitioned store (and
    its overlapped variant) matches the synchronized reference bitwise
    after flush, at >= 2x the reference's GUPS;
11. partitioned memory + wire — resident per-device state drops by
    >= 4x vs the replicated store, and a non-commit partitioned tick
    moves zero collective bytes (reads route to the home shard).

A regression in the classifier (hlo_cost), the permutes, the engine's
stage compilation, or the defer-schedule solver breaks one of these long
before it breaks correctness tests — this is the cost model's canary.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.records import parse_record  # noqa: E402
from repro.analysis.placement import check_noncommit_record  # noqa: E402


def fail(msg: str) -> None:
    print(f"check_level_costs: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    rows = []
    for line in sys.stdin:
        print(line, end="")  # pass the stream through for the log
        rec = parse_record(line)
        if rec is not None:
            rows.append(rec)
    hier = {r.get("case"): r for r in rows if r.get("bench") == "hierarchy"}
    required = ("flat_butterfly", "hier3_rep", "hier3_lane",
                "hier3_defer_amortized", "hier3_defer_auto",
                "hier3_overlap")
    missing = [c for c in required if c not in hier]
    if missing:
        fail(f"missing hierarchy cases {missing} "
             f"(got {sorted(hier)})")

    flat = hier["flat_butterfly"]["wire_bytes_by_level_total"]
    group = hier["flat_butterfly"].get("group_size", 0)
    for case in ("hier3_rep", "hier3_lane"):
        vec = hier[case]["wire_bytes_by_level_total"]
        names = hier[case].get("level_names", [])
        if any(a < b for a, b in zip(vec, vec[1:])):
            fail(f"{case}: per-level bytes {vec} ({names}) not "
                 f"monotonically cheaper at lower levels")
        if vec[-1] <= 0:
            fail(f"{case}: zero top-level bytes {vec}")
        reduction = flat[-1] / vec[-1]
        if reduction < group / 2:
            fail(f"{case}: top-level reduction {reduction:.1f}x vs flat "
                 f"butterfly below group/2 = {group / 2:.0f}x")

    amort = hier["hier3_defer_amortized"]
    k = amort.get("commit_every", 0)
    x = amort.get("top_level_amortization_x") or 0
    if x < k / 2:
        fail(f"deferred commit amortizes top level {x}x < K/2 = {k / 2}")

    auto = hier["hier3_defer_auto"]
    k_auto = auto.get("commit_every", 0)
    if k_auto < 2:
        fail(f"defer schedule solved K={k_auto} under the congested-DCI "
             f"scenario; the solver no longer defers when the top level "
             f"dominates")
    x_auto = auto.get("top_level_amortization_x") or 0
    if x_auto < 0.8 * k_auto:
        fail(f"auto schedule K={k_auto} but measured top-level "
             f"amortization {x_auto}x < 0.8*K; realized commit traffic "
             f"does not match the solver's prediction "
             f"(predicted {auto.get('predicted_amortization_x')}x)")

    ovl = hier["hier3_overlap"]
    hidden = ovl.get("hidden_frac") or 0
    if hidden < 0.5:
        fail(f"overlapped commit hides only {hidden:.0%} of the top-level "
             f"exchange time (exchange "
             f"{ovl.get('top_exchange_time_us')}us vs compute "
             f"{ovl.get('overlap_compute_time_us')}us); the launch/land "
             f"pipeline no longer hides the commit behind the next step's "
             f"compute")
    k_ser = ovl.get("k_serialized")
    k_ovl = ovl.get("k_overlap")
    if k_ser is not None and k_ovl is not None and k_ovl > k_ser:
        fail(f"overlap-aware solver picked K={k_ovl} > serialized K={k_ser}; "
             f"hiding the exchange must never make deferring *more* "
             f"attractive")

    apps = [r for r in rows if r.get("bench") == "apps_sharded"]
    apps_msg = ""
    if apps:
        errs = [r for r in apps if "error" in r]
        if errs:
            fail(f"apps_sharded subprocess failed: {errs[0]['error']}")
        cors = [r for r in apps if "defer_max_err" in r]
        if not cors:
            fail("apps_sharded records present but no correctness rows")
        for r in cors:
            app, case = r.get("app"), r.get("case")
            if app == "bfs":
                if r.get("eager_max_err") != 0.0 or r["defer_max_err"] != 0.0:
                    fail(f"{case}: BFS no longer bitwise (eager "
                         f"{r.get('eager_max_err')}, defer "
                         f"{r['defer_max_err']}); the MIN lattice join must "
                         f"reproduce the reference exactly")
            else:
                tol = 1e-4 if app == "pagerank" else 1e-3
                worst = max(v for key_, v in r.items()
                            if key_.endswith("_max_err"))
                if worst > tol:
                    fail(f"{case}: max err {worst} above tolerance {tol}")
        amorts = [r for r in apps
                  if str(r.get("case", "")).startswith(
                      "pagerank_defer_amortized")]
        if not amorts:
            fail("apps_sharded present but no pagerank_defer_amortized "
                 "record; the deferred-superstep wire study did not run")
        for r in amorts:
            ka = r.get("commit_every", 0)
            xa = r.get("top_level_amortization_x") or 0
            if xa < ka / 2:
                fail(f"{r['case']}: deferred supersteps amortize top-level "
                     f"bytes {xa}x < K/2 = {ka / 2}; the :defer plan no "
                     f"longer skips the cross-pod exchange between commits")
        apps_msg = (f", apps: {len(cors)} correctness rows OK, pagerank "
                    f"defer amortization "
                    f"{[r.get('top_level_amortization_x') for r in amorts]}x")

    kv = [r for r in rows if r.get("bench") == "kv_gups"]
    kv_msg = ""
    if kv:
        errs = [r for r in kv if "error" in r]
        if errs:
            fail(f"kv_gups subprocess failed: {errs[0]['error']}")
        cases = {r.get("case"): r for r in kv if "case" in r}

        def _kv(prefix):
            return next((r for c, r in cases.items()
                         if str(c).startswith(prefix)), None)

        bit = _kv("bitwise")
        if bit is None or not bit.get("match"):
            fail(f"kv_gups: privatized-deferred store no longer matches "
                 f"the synchronized reference bitwise after flush "
                 f"(record {bit}); the speedup is over a *different* "
                 f"eventual table")
        sp = _kv("pareto_speedup")
        if sp is None:
            fail("kv_gups records present but no pareto_speedup row")
        sx = sp.get("gups_speedup_x") or 0
        if sx < 2.0:
            fail(f"kv_gups: privatized serving only {sx}x sync GUPS on "
                 f"the Pareto-skewed trace (< 2x); the deferred merge "
                 f"bill no longer amortizes")
        step = _kv("kv_defer_step")
        if step is None:
            fail("kv_gups records present but no kv_defer_step row; the "
                 "non-commit wire walk did not run")
        # Shared with the static verifier (repro.analysis) so the canary
        # and `scripts/lint_plans.py` cannot drift apart on what "zero
        # non-commit collectives" means.
        diag = check_noncommit_record(step, site=f"kv_gups:{step.get('case')}")
        if diag is not None:
            fail(f"kv_gups: {diag.format()}")
        am = _kv("kv_defer_amortized")
        if am is None:
            fail("kv_gups records present but no kv_defer_amortized row")
        kk = am.get("commit_every", 0)
        kx = am.get("top_level_amortization_x") or 0
        if kx < kk / 2:
            fail(f"kv_gups: K-cycle top-level bytes amortize only {kx}x "
                 f"< K/2 = {kk / 2}")
        kv_msg = (f", kv: bitwise OK, pareto speedup {sx}x, "
                  f"amortization {kx}x/K={kk}")

        # partitioned serving tier: home-sharded settled table with
        # spill-through-eviction pendings and overlapped commits
        pbit = _kv("kv_part_bitwise")
        if pbit is not None:
            if not pbit.get("match") or not pbit.get("match_overlap"):
                fail(f"kv_gups: partitioned store diverges from the "
                     f"synchronized reference after flush (record {pbit}); "
                     f"home routing or the launch/land split lost updates")
            psp = _kv("pareto_part_speedup")
            if psp is None:
                fail("kv_gups partitioned records present but no "
                     "pareto_part_speedup row")
            px = psp.get("gups_speedup_x") or 0
            if px < 2.0:
                fail(f"kv_gups: partitioned serving only {px}x sync GUPS "
                     f"on the Pareto-skewed trace (< 2x); partitioning "
                     f"must not forfeit the deferred-commit win")
            foot = _kv("kv_part_footprint")
            if foot is None:
                fail("kv_gups partitioned records present but no "
                     "kv_part_footprint row")
            dx = foot.get("resident_drop_x") or 0
            if dx < 4.0:
                fail(f"kv_gups: partitioned resident state only {dx}x "
                     f"smaller than the replicated store (< 4x); the "
                     f"home-sharded table no longer bounds per-device "
                     f"memory")
            pstep = _kv("kv_part_step")
            if pstep is None:
                fail("kv_gups partitioned records present but no "
                     "kv_part_step row; the routed-read wire walk did "
                     "not run")
            diag = check_noncommit_record(
                pstep, site=f"kv_gups:{pstep.get('case')}")
            if diag is not None:
                fail(f"kv_gups: {diag.format()}")
            kv_msg += (f", partitioned: speedup {px}x, "
                       f"resident drop {dx}x")

    print(f"check_level_costs: OK (top-level reduction "
          f"{flat[-1] / hier['hier3_lane']['wire_bytes_by_level_total'][-1]:.0f}x, "
          f"defer amortization {x}x/K={k}, "
          f"auto schedule K={k_auto} -> {x_auto}x, "
          f"overlap hides {hidden:.0%} of the top-level exchange, "
          f"K {k_ser} -> {k_ovl}{apps_msg}{kv_msg})", file=sys.stderr)


if __name__ == "__main__":
    main()
