#!/usr/bin/env python
"""Static MergePlan lint sweep — thin wrapper over ``python -m
repro.analysis`` so CI and humans share one entry point.

Sweeps every config in src/repro/configs/, every app superstep in
src/repro/apps/, every shipped merge fn, and the ShardedKV serving plans
on a forced 8-way host mesh; fails with stable CC diagnostic codes
(docs/static_analysis.md). Typical CI invocation::

    python scripts/lint_plans.py --json lint_report.json

Suppress a finding per site with ``--suppress CC021@kv[all]``; run the
seeded-violation canaries with ``--fixtures``.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
