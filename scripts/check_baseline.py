#!/usr/bin/env python
"""CI perf gate: benchmark records vs the checked-in baseline.

Reads the tagged ``@repro-bench`` record stream on stdin (passed through
unchanged, so it chains after ``check_level_costs.py``), loads
``benchmarks/baseline.json``, and FAILS when a gated metric regresses past
its bound — perf regressions break CI instead of only printing.

Baseline format::

    {
      "summary": {"<summary key>": {"min": v} | {"max": v}},
      "cases": [{"bench": ..., "case": ..., "metric": ..., "min"/"max": v}]
    }

``min`` bounds guard benefits (speedups, reduction factors, hidden
fractions — regressing means falling below); ``max`` bounds guard costs
(simulated times, wire bytes — regressing means growing past). ``metric``
may use ``name.index`` to index into a list (e.g.
``wire_bytes_by_level_total.-1`` for the top level).

Regenerate after an intentional perf change::

    PYTHONPATH=src:. python -m benchmarks.run --quick \
        --only fig6,hier,fabric,apps_sharded \
        | python scripts/check_baseline.py --write benchmarks/baseline.json

The generator derives bounds from the current run with a 10% margin in the
non-regressing direction.

``--write-new`` extends a baseline *per record* instead of regenerating it
wholesale: every existing bound present in the run is still gated (a
regression fails without writing anything), bounds that the run does not
produce are kept unchanged, and gated keys that have no bound yet are
seeded from the current run with the 10% margin. Use it when a PR adds
new benchmark cells — the new metrics get bounds without re-deriving (and
silently loosening or tightening) the old ones::

    ... | python scripts/check_baseline.py --write-new benchmarks/baseline.json
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.records import duplicate_record_keys, parse_record  # noqa: E402

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "baseline.json")
MARGIN = 0.10

# Summary keys gated at generation time: True = benefit (min bound),
# False = cost (max bound).
SUMMARY_KEYS = {
    "fig6_ccache_speedup_max": True,
    "fig6_ccache_speedup_min": True,
    "hier_inter_wire_reduction_x": True,
    "hier_sim_speedup_x": True,
    "hier3_top_level_reduction_x": True,
    "hier3_defer_amortization_x": True,
    "hier3_defer_auto_measured_x": True,
    "hier3_overlap_hidden_frac": True,
    "fabric_top_level_reduction_x": True,
    "fabric_lane_vs_rep_speedup_x": True,
    "fabric_defer_top_amortization_x": True,
    "fabric_hier_vs_flat_speedup_x": True,
    "fabric_overlap_top_hidden_frac": True,
    "apps_bfs_defer_amortization_x": True,
    "apps_pagerank_defer_amortization_x": True,
    "kv_gups_speedup_skewed_x": True,
    "kv_gups_speedup_uniform_x": True,
    "kv_defer_amortization_x": True,
    "kv_part_speedup_x": True,
    "kv_part_resident_drop_x": True,
}

# (bench, case, metric, benefit?) gated per-record at generation time.
CASE_METRICS = [
    ("hierarchy", "flat_butterfly", "sim_time_us", False),
    ("hierarchy", "hierarchical", "sim_time_us", False),
    ("hierarchy", "hier3_rep", "sim_time_us", False),
    ("hierarchy", "hier3_lane", "sim_time_us", False),
    ("hierarchy", "hier3_lane", "wire_bytes_by_level_total.-1", False),
    ("hierarchy", "hier3_defer_amortized", "sim_time_us", False),
    ("hierarchy", "hier3_overlap", "hidden_frac", True),
    ("hierarchy", "hier3_overlap", "exposed_time_us", False),
    ("fabric", "flat_butterfly", "time_s", False),
    ("fabric", "hier_lane", "time_s", False),
    ("fabric", "hier_lane_defer8_overlap", "time_s", False),
    # apps_sharded: the 8-shard mesh runs in both quick and full mode.
    ("apps_sharded", "bfs_defer_amortized_s8",
     "top_level_amortization_x", True),
    ("apps_sharded", "pagerank_defer_amortized_s8",
     "top_level_amortization_x", True),
    # kv_gups: the serving tier's GUPS contest on the forced 8-way mesh.
    ("kv_gups", "pareto_speedup_s8", "gups_speedup_x", True),
    ("kv_gups", "kv_defer_amortized_s8", "top_level_amortization_x", True),
    # partitioned serving tier: home-sharded table + overlapped commits.
    ("kv_gups", "pareto_part_speedup_s8", "gups_speedup_x", True),
    ("kv_gups", "kv_part_footprint_s8", "resident_drop_x", True),
]


def fail(msg: str) -> None:
    print(f"check_baseline: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def lookup(rec: dict, metric: str):
    cur = rec
    for part in metric.split("."):
        try:
            cur = cur[int(part)] if isinstance(cur, list) else cur.get(part)
        except (IndexError, ValueError, AttributeError):
            return None
        if cur is None:
            return None
    return cur


def collect(stream) -> tuple[dict, list[dict]]:
    summary = {}
    rows = []
    records = []
    for line in stream:
        print(line, end="")  # pass the stream through for the log
        rec = parse_record(line)
        if rec is None:
            continue
        records.append(rec)
        if "summary" in rec:
            summary = rec["summary"]
        else:
            rows.append(rec)
    # A duplicated key would make find()/the summary dict silently pick one
    # value and gate against it — fail loudly with both values instead
    # (diagnostic CC030 in the static-analysis catalog).
    dups = duplicate_record_keys(records)
    if dups:
        fail("CC030 duplicate record keys: " + "; ".join(dups))
    return summary, rows


def find(rows, bench, case):
    for r in rows:
        if r.get("bench") == bench and r.get("case") == case:
            return r
    return None


def write_baseline(path: str, summary: dict, rows: list[dict]) -> None:
    out = {"summary": {}, "cases": []}
    for key, benefit in SUMMARY_KEYS.items():
        v = summary.get(key)
        if not isinstance(v, (int, float)):
            continue
        bound = {"min": round(v * (1 - MARGIN), 6)} if benefit \
            else {"max": round(v * (1 + MARGIN), 6)}
        out["summary"][key] = bound
    for bench, case, metric, benefit in CASE_METRICS:
        rec = find(rows, bench, case)
        v = lookup(rec, metric) if rec else None
        if not isinstance(v, (int, float)):
            continue
        entry = {"bench": bench, "case": case, "metric": metric}
        entry.update({"min": round(v * (1 - MARGIN), 6)} if benefit
                     else {"max": round(v * (1 + MARGIN), 6)})
        out["cases"].append(entry)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"check_baseline: wrote {path} ({len(out['summary'])} summary "
          f"bounds, {len(out['cases'])} case bounds)", file=sys.stderr)


def audit(base: dict, summary: dict, rows: list[dict],
          require_present: bool = True) -> list[str]:
    """Gate the run against every bound in ``base``; returns problems.

    ``require_present=False`` (the ``--write-new`` mode) skips bounds the
    run does not produce instead of flagging them — a partial run may
    extend a baseline but can never regress the parts it did produce.
    """
    problems = []
    for key, bound in base.get("summary", {}).items():
        v = summary.get(key)
        if not isinstance(v, (int, float)):
            if require_present:
                problems.append(f"summary key {key!r} missing from the run")
            continue
        if "min" in bound and v < bound["min"]:
            problems.append(f"summary {key} = {v} regressed below baseline "
                            f"min {bound['min']}")
        if "max" in bound and v > bound["max"]:
            problems.append(f"summary {key} = {v} regressed above baseline "
                            f"max {bound['max']}")
    for entry in base.get("cases", []):
        rec = find(rows, entry["bench"], entry["case"])
        if rec is None:
            if require_present:
                problems.append(f"record {entry['bench']}/{entry['case']} "
                                f"missing from the run")
            continue
        v = lookup(rec, entry["metric"])
        if not isinstance(v, (int, float)):
            if require_present:
                problems.append(f"{entry['bench']}/{entry['case']}: metric "
                                f"{entry['metric']!r} missing")
            continue
        where = f"{entry['bench']}/{entry['case']}.{entry['metric']}"
        if "min" in entry and v < entry["min"]:
            problems.append(f"{where} = {v} regressed below baseline "
                            f"min {entry['min']}")
        if "max" in entry and v > entry["max"]:
            problems.append(f"{where} = {v} regressed above baseline "
                            f"max {entry['max']}")
    return problems


def check(path: str, summary: dict, rows: list[dict]) -> None:
    with open(path) as f:
        base = json.load(f)
    problems = audit(base, summary, rows)
    if problems:
        fail("; ".join(problems)
             + " (intentional change? regenerate with --write, see module "
               "docstring)")
    n = len(base.get("summary", {})) + len(base.get("cases", []))
    print(f"check_baseline: OK ({n} bounds held)", file=sys.stderr)


def _bound(v: float, benefit: bool) -> dict:
    return {"min": round(v * (1 - MARGIN), 6)} if benefit \
        else {"max": round(v * (1 + MARGIN), 6)}


def write_new_baseline(path: str, summary: dict, rows: list[dict]) -> None:
    """Extend ``path`` per record: gate what exists, seed what doesn't."""
    base = {"summary": {}, "cases": []}
    if os.path.exists(path):
        with open(path) as f:
            base = json.load(f)
    problems = audit(base, summary, rows, require_present=False)
    if problems:
        fail("; ".join(problems)
             + " (--write-new refuses to extend a baseline the run "
               "regresses; fix the regression or regenerate with --write)")
    added = []
    for key, benefit in SUMMARY_KEYS.items():
        if key in base.setdefault("summary", {}):
            continue
        v = summary.get(key)
        if isinstance(v, (int, float)):
            base["summary"][key] = _bound(v, benefit)
            added.append(f"summary:{key}")
    have = {(e["bench"], e["case"], e["metric"])
            for e in base.setdefault("cases", [])}
    for bench, case, metric, benefit in CASE_METRICS:
        if (bench, case, metric) in have:
            continue
        rec = find(rows, bench, case)
        v = lookup(rec, metric) if rec else None
        if isinstance(v, (int, float)):
            base["cases"].append({"bench": bench, "case": case,
                                  "metric": metric, **_bound(v, benefit)})
            added.append(f"{bench}/{case}.{metric}")
    with open(path, "w") as f:
        json.dump(base, f, indent=1)
        f.write("\n")
    print(f"check_baseline: extended {path} with {len(added)} new bounds "
          f"({', '.join(added) if added else 'none'}); existing bounds "
          f"held", file=sys.stderr)


def main() -> None:
    args = sys.argv[1:]
    if args and args[0] == "--write":
        path = args[1] if len(args) > 1 else DEFAULT_BASELINE
        summary, rows = collect(sys.stdin)
        write_baseline(path, summary, rows)
        return
    if args and args[0] == "--write-new":
        path = args[1] if len(args) > 1 else DEFAULT_BASELINE
        summary, rows = collect(sys.stdin)
        write_new_baseline(path, summary, rows)
        return
    path = args[0] if args else DEFAULT_BASELINE
    if not os.path.exists(path):
        fail(f"baseline {path} not found; generate it with --write")
    summary, rows = collect(sys.stdin)
    check(path, summary, rows)


if __name__ == "__main__":
    main()
