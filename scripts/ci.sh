#!/usr/bin/env bash
# Tier-1 gate: the full offline test suite plus the quick benchmark cells
# (paper fig6, the hierarchical-merge wire comparison on a 3-level
# chip/host/pod topology, and the analytic fabric model), with the
# per-level wire-byte vector checked for cost-model regressions: bytes must
# be monotonically cheaper at lower levels, the top level must shrink by
# ~the group factor vs the flat butterfly, the merge-on-evict commit must
# amortize top-level traffic by ~K, and the roofline-solved defer schedule
# (hier3_defer_auto, congested-DCI scenario) must pick K >= 2 and realize
# >= 0.8*K measured amortization (scripts/check_level_costs.py). The
# benchmark stream is tagged JSON records (benchmarks/records.py), so stray
# log lines cannot poison the gate.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --quick --only fig6,hier,fabric \
    | python scripts/check_level_costs.py
