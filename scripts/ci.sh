#!/usr/bin/env bash
# Tier-1 gate, five stages:
#
# 1. fast tests — the offline suite minus the slow-marked subprocess tests;
# 2. slow tests — the subprocess CLI / multi-device end-to-end tests, run
#    as their own timed stage so latency regressions are visible in the log;
# 3. static lint — scripts/lint_plans.py (docs/static_analysis.md): the
#    seeded-violation canaries first (every known-bad input must trip its
#    stable CC code), then the full sweep — merge-fn trait certification,
#    plan audits for every config on the production mesh geometries, the
#    app supersteps traced collective-free, and the ShardedKV serving
#    plans lowered on a forced 8-way host mesh with their compiled
#    collectives checked against the ccache manifests and their donated
#    buffers checked as aliased. Failures print the CC code plus the
#    offending plan/level, before any benchmark money is spent;
# 4. benchmark gate — the quick benchmark cells (paper fig6, the
#    hierarchical-merge wire comparison on a 3-level chip/host/pod
#    topology, the analytic fabric model, the sharded-apps
#    mesh-scaling study: BFS/PageRank/k-means as MergePlan programs on a
#    forced 8-device mesh, BFS gated bitwise and the PageRank deferred
#    supersteps gated on top-level amortization, and the kv_gups serving
#    study: the sharded commutative KV store gated bitwise-after-flush,
#    >= 2x sync GUPS on the Pareto trace, zero non-commit collectives,
#    and >= K/2 top-level amortization), checked twice:
#      * scripts/check_level_costs.py asserts the cost-model invariants:
#        per-level bytes monotonically cheaper at lower levels, top level
#        shrunk by ~the group factor vs the flat butterfly, merge-on-evict
#        amortizing by ~K, the roofline-solved defer schedule
#        (hier3_defer_auto, congested-DCI) picking K >= 2 with >= 0.8*K
#        measured amortization, and the overlapped commit (hier3_overlap)
#        hiding >= 50% of the top-level exchange time behind compute;
#      * scripts/check_baseline.py --write-new gates the same record
#        stream against the checked-in benchmarks/baseline.json, so perf
#        regressions in the gated metrics FAIL CI instead of only
#        printing, and seeds bounds for newly-added cells (regenerate
#        with --write after an intentional perf change);
# 5. fault-tolerance gate — the chaos acceptance suite
#    (docs/fault_tolerance.md): preemption/kill sweeps over the integer
#    deferred cascade recovered bitwise, the volatile-spec/CC040 audit,
#    an elastic restore onto a different merge topology with zero mass
#    loss, KV journal+snapshot crash recovery onto 2x shards, and a
#    real-model deferred run killed mid-cycle on a forced 8-device mesh.
#
# The benchmark stream is tagged JSON records (benchmarks/records.py), so
# stray log lines cannot poison either gate.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "=== stage 1: fast tests ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow"

echo "=== stage 2: slow tests (timed) ==="
time PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m slow

echo "=== stage 3: static plan lint ==="
python scripts/lint_plans.py --fixtures
python scripts/lint_plans.py

echo "=== stage 4: benchmark gate ==="
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --quick \
    --only fig6,hier,fabric,apps_sharded,kv_gups \
    | python scripts/check_level_costs.py \
    | python scripts/check_baseline.py --write-new benchmarks/baseline.json

echo "=== stage 5: fault-tolerance gate ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/fault_tolerant_train.py --chaos --quick
