#!/usr/bin/env bash
# Tier-1 gate: the full offline test suite plus the quick benchmark cells
# (paper fig6, the hierarchical-merge wire comparison on a 3-level
# chip/host/pod topology, and the analytic fabric model), with the
# per-level wire-byte vector checked for cost-model regressions: bytes must
# be monotonically cheaper at lower levels, the top level must shrink by
# ~the group factor vs the flat butterfly, and the merge-on-evict commit
# must amortize top-level traffic by ~K (scripts/check_level_costs.py).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --quick --only fig6,hier,fabric \
    | python scripts/check_level_costs.py
