#!/usr/bin/env bash
# Tier-1 gate: the full offline test suite plus the quick benchmark cells
# (paper fig6 + the hierarchical-merge wire comparison).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --quick --only fig6,hier
