"""Fault-tolerance demo: NaN batches, preemption, restart-and-resume.

    PYTHONPATH=src python examples/fault_tolerant_train.py

Phase 1 trains with a data stream that poisons one batch (NaN loss) — the
driver skips it and keeps going. Phase 2 requests preemption mid-run (what
SIGTERM does); the driver saves at the step boundary and exits. Phase 3
restarts from the committed checkpoint and finishes, bit-identically to an
uninterrupted run over the same (step-indexed, deterministic) data stream.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_smoke_config
from repro.data.pipeline import batch_at, data_config_for
from repro.launch.steps import make_train_step
from repro.models.module import split_params
from repro.models.registry import build_model
from repro.optim import adamw, constant
from repro.runtime import DriverConfig, TrainDriver


def main() -> None:
    cfg = get_smoke_config("internlm2_1_8b")
    shape = ShapeConfig("ft", 32, 4, "train")
    model = build_model(cfg)
    opt = adamw(constant(1e-3))
    step_fn = jax.jit(make_train_step(model, cfg, opt, 1))
    params, _ = split_params(model.init(jax.random.key(0)))
    state0 = {"params": params, "opt": opt.init(params)}
    dcfg = data_config_for(cfg, shape, seed=0)

    def batch_fn(i):
        b = jax.tree.map(jnp.asarray, batch_at(dcfg, i))
        b["poison"] = jnp.asarray(float("nan") if i == 4 else 0.0)
        return b

    raw_step = step_fn

    def step_fn_injected(state, b):
        poison = b.pop("poison")
        new_state, metrics = raw_step(state, b)
        # injected fault: emulate a corrupt batch poisoning the loss
        metrics = dict(metrics, loss=metrics["loss"] + poison)
        return new_state, metrics

    with tempfile.TemporaryDirectory() as d:
        drv = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=5,
                                       retry_backoff_s=0.0),
                          step_fn=step_fn_injected, batch_fn=batch_fn)

        print("phase 1: train through a poisoned batch")
        state, end = drv.run(state0, 0, 8)
        nans = [e for e in drv.events if e["event"] == "nan_rollback"]
        print(f"  reached step {end}; skipped {len(nans)} poisoned batch")

        print("phase 2: preempt mid-run (SIGTERM semantics)")
        drv2 = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=100),
                           step_fn=step_fn_injected, batch_fn=batch_fn)
        orig = drv2.batch_fn
        def preempting(i):
            if i == end + 2:
                drv2._preempted = True
            return orig(i)
        drv2.batch_fn = preempting
        state, end2 = drv2.run(state, end, 20)
        print(f"  preempted; checkpoint committed at step "
              f"{ckpt.latest_step(d)}")

        print("phase 3: restart from the committed checkpoint")
        restored, extras = ckpt.restore(d, state)
        drv3 = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=10),
                           step_fn=step_fn_injected, batch_fn=batch_fn)
        state, end3 = drv3.run(restored, extras["next_step"], 5)
        losses = [e for e in drv3.events if e["event"] == "step"]
        print(f"  resumed {extras['next_step']} -> {end3}; "
              f"final loss {losses[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
