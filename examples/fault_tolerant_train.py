"""Fault-tolerance demo: NaN batches, preemption, kills, elastic resume.

    PYTHONPATH=src python examples/fault_tolerant_train.py            # demo
    PYTHONPATH=src python examples/fault_tolerant_train.py --chaos    # full
    PYTHONPATH=src python examples/fault_tolerant_train.py --chaos --quick

Default mode is the classic three-phase driver demo: train through a
poisoned (NaN) batch, preempt mid-run (SIGTERM semantics — save at the
step boundary and exit), restart from the committed checkpoint.

``--chaos`` is the durability acceptance run for *deferred-commit* state
(``state["defer"]``: the pending cascade + an overlapped in-flight
launch):

1. toy integer sweep — preemption at EVERY step boundary and hard kills
   mid-cycle/mid-launch must recover bitwise-identically to the
   uninterrupted run (``repro.runtime.chaos``);
2. volatile-spec audit — the checkpoint-coverage spec (CC040) must match
   the real defer state, key for key;
3. real-model deferred train (forced 8-device host mesh, overlapped
   K=2 cascade) — kill the driver between steps, resume, and compare
   params bitwise against the uninterrupted twin;
4. elastic restore — take a mid-cycle checkpoint onto a DIFFERENT merge
   topology: outstanding mass settles into params/opt (vs. the
   flush-under-old-topology oracle) and the defer-aware LR/beta rescale
   reports the hyperparameters that keep per-data-step dynamics fixed;
5. serving tier — journal + snapshot a ShardedKV, crash it mid-epoch,
   recover onto a different shard count, and match the numpy oracle
   bitwise.
"""

import argparse
import os
import sys


def _parse_args():
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--chaos", action="store_true",
                   help="run the deferred-state durability acceptance suite")
    p.add_argument("--quick", action="store_true",
                   help="with --chaos: fewer kill points / smaller sweeps "
                        "(the CI configuration)")
    return p.parse_args()


ARGS = _parse_args()
if ARGS.chaos:
    # the real-model phase runs an explicit 8-way merge mesh on host CPU;
    # must be set before jax initializes its backends
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_smoke_config
from repro.data.pipeline import batch_at, data_config_for
from repro.launch.steps import make_train_step
from repro.models.module import split_params
from repro.models.registry import build_model
from repro.optim import adamw, constant
from repro.runtime import DriverConfig, TrainDriver


def demo() -> None:
    cfg = get_smoke_config("internlm2_1_8b")
    shape = ShapeConfig("ft", 32, 4, "train")
    model = build_model(cfg)
    opt = adamw(constant(1e-3))
    step_fn = jax.jit(make_train_step(model, cfg, opt, 1))
    params, _ = split_params(model.init(jax.random.key(0)))
    state0 = {"params": params, "opt": opt.init(params)}
    dcfg = data_config_for(cfg, shape, seed=0)

    def batch_fn(i):
        b = jax.tree.map(jnp.asarray, batch_at(dcfg, i))
        b["poison"] = jnp.asarray(float("nan") if i == 4 else 0.0)
        return b

    raw_step = step_fn

    def step_fn_injected(state, b):
        poison = b.pop("poison")
        new_state, metrics = raw_step(state, b)
        # injected fault: emulate a corrupt batch poisoning the loss
        metrics = dict(metrics, loss=metrics["loss"] + poison)
        return new_state, metrics

    with tempfile.TemporaryDirectory() as d:
        drv = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=5,
                                       retry_backoff_s=0.0),
                          step_fn=step_fn_injected, batch_fn=batch_fn)

        print("phase 1: train through a poisoned batch")
        state, end = drv.run(state0, 0, 8)
        nans = [e for e in drv.events if e["event"] == "nan_rollback"]
        print(f"  reached step {end}; skipped {len(nans)} poisoned batch")

        print("phase 2: preempt mid-run (SIGTERM semantics)")
        drv2 = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=100),
                           step_fn=step_fn_injected, batch_fn=batch_fn)
        orig = drv2.batch_fn

        def preempting(i):
            if i == end + 2:
                drv2._preempted = True
            return orig(i)
        drv2.batch_fn = preempting
        state, end2 = drv2.run(state, end, 20)
        print(f"  preempted; checkpoint committed at step "
              f"{ckpt.latest_step(d)}")

        print("phase 3: restart from the committed checkpoint")
        drv3 = TrainDriver(DriverConfig(ckpt_dir=d, ckpt_every=10),
                           step_fn=step_fn_injected, batch_fn=batch_fn)
        restored, start, _ = drv3.resume(state)
        state, end3 = drv3.run(restored, start, 5)
        losses = [e for e in drv3.events if e["event"] == "step"]
        print(f"  resumed {start} -> {end3}; "
              f"final loss {losses[-1]['loss']:.4f}")


# ---------------------------------------------------------------------------
# --chaos: deferred-state durability acceptance
# ---------------------------------------------------------------------------


def chaos_toy_sweeps(quick: bool) -> None:
    from repro.runtime import chaos

    n_steps = 5 if quick else 8
    print(f"[toy] preempt at every boundary + kills, {n_steps} steps, "
          f"2-level overlapped cascade, integer ADD")
    fac = chaos.toy_factory("chip:2,host:2:defer,pod:2:defer", (1, 2), 8,
                            width=4, overlap=True)
    with tempfile.TemporaryDirectory() as root:
        for mode in ("preempt", "kill"):
            kill_steps = ([1, 3] if quick else None)  # None = every boundary
            _, outcomes = chaos.chaos_sweep(
                fac, n_steps, os.path.join(root, mode), mode=mode,
                kill_steps=kill_steps)
            bad = [o for o in outcomes if not o.state_bitwise]
            assert not bad, f"{mode}: non-bitwise recoveries {bad}"
            print(f"  {mode}: {len(outcomes)}/{len(outcomes)} boundaries "
                  f"recovered bitwise (actions: "
                  f"{sorted({o.resume_action for o in outcomes}, key=str)})")
        # flush policy: mass conserved (params bitwise for integer ADD),
        # optimizer fold count legitimately differs
        _, outcomes = chaos.chaos_sweep(
            fac, n_steps, os.path.join(root, "flush"), mode="preempt",
            defer_save="flush", kill_steps=[1, 3])
        assert all(o.params_bitwise for o in outcomes)
        print("  flush policy: params bitwise (mass conserved), "
              "opt sequencing differs as documented")


def chaos_spec_audit() -> None:
    from repro.analysis.durability import check_step_durability
    from repro.checkpoint import tree_keys
    from repro.runtime import chaos

    step, _, state0 = chaos.toy_factory(
        "chip:2,host:2:defer,pod:2:defer", (2, 4), 8, width=4,
        overlap=True)()
    spec = step.volatile_spec(state0["params"])
    assert tree_keys(spec) == tree_keys(state0["defer"]), \
        "volatile spec drifted from the real defer state"
    assert not check_step_durability("example:toy", step, state0["params"])
    print("[spec] volatile spec == real defer state "
          f"({len(tree_keys(spec))} leaves); CC040 clean")


def chaos_real_model(quick: bool) -> None:
    from repro.core.defer_schedule import DeferSchedule
    from repro.core.merge_plan import MergePlan
    from repro.launch.steps import lowering_rules
    from repro.runtime import chaos
    from repro.sharding.partition import sharding_rules

    n_steps = 5
    kill_points = [2] if quick else [1, 2, 3, 4]
    print(f"[real] xlstm-125m, 8-way mesh, overlapped K=2 cascade; kills "
          f"at {kill_points} of {n_steps} steps")

    cfg = get_smoke_config("xlstm_125m")
    shape = ShapeConfig("t", 32, 8, "train")
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    rules = lowering_rules(cfg, shape, mesh)
    model = build_model(cfg)
    opt = adamw(constant(1e-3))
    plan = MergePlan.parse("chip:2,host:2,pod:2:defer", lane_parallel=True)
    sched = DeferSchedule.fixed(2, ("pod",), overlap=True)
    dcfg = data_config_for(cfg, shape, seed=0)

    def batch_fn(i):
        return jax.tree.map(jnp.asarray, batch_at(dcfg, i))

    with mesh, sharding_rules(mesh, rules):
        step = make_train_step(model, cfg, opt, 1, mesh=mesh,
                               merge_topology=plan, defer_schedule=sched)
        params, _ = split_params(model.init(jax.random.key(0)))
        state0 = {"params": params, "opt": opt.init(params),
                  "defer": step.init_defer_state(params)}
        fn = step.jit()

        # uninterrupted twin
        base = state0
        for i in range(n_steps):
            base, _ = fn(base, batch_fn(i))
        base, _ = step.flush(base)
        base_params = jax.tree.map(np.asarray, base["params"])

        for kill in kill_points:
            with tempfile.TemporaryDirectory() as d:
                dcfg_drv = DriverConfig(ckpt_dir=d, ckpt_every=1,
                                        retry_backoff_s=0.0)
                drv = TrainDriver(dcfg_drv, fn,
                                  chaos.crashing(batch_fn, kill),
                                  defer_step=step)
                try:
                    drv.run(state0, 0, n_steps)
                    raise AssertionError("crash did not fire")
                except chaos.SimulatedCrash:
                    pass
                drv2 = TrainDriver(dcfg_drv, fn, batch_fn, defer_step=step)
                state, start, report = drv2.resume(state0)
                state, _ = drv2.run(state, start, n_steps - start)
                state, _ = step.flush(state)
                got = jax.tree.map(np.asarray, state["params"])
                same = all(
                    np.array_equal(a, b) for a, b in
                    zip(jax.tree.leaves(base_params),
                        jax.tree.leaves(got)))
                assert same, f"kill@{kill}: params diverged after recovery"
                print(f"  kill@{kill}: resumed "
                      f"({report.action if report else 'fresh'} at step "
                      f"{start}) -> params BITWISE equal")


def chaos_elastic(quick: bool) -> None:
    from repro.runtime import chaos
    from repro.runtime.elastic import effective_invariants, \
        rescale_hyperparams

    print("[elastic] mid-cycle checkpoint restored onto a different "
          "topology (K=2 two-level overlap -> K=3 single-level)")
    fac_old = chaos.toy_factory("chip:2,host:2:defer,pod:2:defer", (1, 2),
                                8, width=4, overlap=True)
    fac_new = chaos.toy_factory("chip:4,pod:2:defer", (3,), 8, width=4)
    with tempfile.TemporaryDirectory() as d:
        step_o, bf_o, st_o = fac_old()
        cfg = DriverConfig(ckpt_dir=d, ckpt_every=5)
        TrainDriver(cfg, step_o, bf_o, defer_step=step_o).run(st_o, 0, 5)

        # oracle: restore under the OLD topology, flush everything
        step_v, bf_v, like_v = fac_old()
        sv, _, _ = TrainDriver(cfg, step_v, bf_v,
                               defer_step=step_v).resume(like_v)
        sv, _ = step_v.flush(sv)

        # elastic: restore under the NEW topology — outstanding mass must
        # settle into params/opt, then fresh defer state is handed out
        step_n, bf_n, like_n = fac_new()
        drv_n = TrainDriver(cfg, step_n, bf_n, defer_step=step_n)
        sn, start, report = drv_n.resume(like_n)
        assert report.action == "resolved", report
        assert np.array_equal(np.asarray(sn["params"]["w"]),
                              np.asarray(sv["params"]["w"])), \
            "elastic settle lost mass"
        assert int(sn["defer"]["t"]) == 0
        h = rescale_hyperparams(report.k_old, report.k_new, lr=1e-3)
        inv_old = effective_invariants(report.k_old, lr=1e-3)
        inv_new = effective_invariants(report.k_new, **h)
        assert np.allclose(inv_old["lr_per_step"], inv_new["lr_per_step"])
        sn, end = drv_n.run(sn, start, 3)
        print(f"  settled {report.flushed_steps} trailing step(s), "
              f"inflight={report.landed_inflight}; mass conserved bitwise; "
              f"continued {start}->{end} under K={report.k_new} with "
              f"lr'={h['lr']:.2e}, b1'={h['b1']:.4f} "
              f"(per-data-step lr invariant)")


def chaos_serving(quick: bool) -> None:
    from repro.serve import KVConfig, ShardedKV, serving_plan

    S, B, R, D, T = 4, 8, 64, 2, 12 if quick else 24
    print(f"[serve] journal+snapshot a {S}-shard KV, crash mid-epoch, "
          f"recover onto {2 * S} partitioned shards")

    def spmd(fn, *args):
        return jax.vmap(fn, axis_name="shards")(*args)

    rng = np.random.default_rng(7)
    keys = rng.integers(0, R, (T, S, B)).astype(np.int32)
    keys[:, :, -1] = -1
    vals = rng.integers(1, 9, (T, S, B, D)).astype(np.int32)
    oracle = np.zeros((R, D), np.int64)
    for t in range(T):
        m = keys[t] >= 0
        np.add.at(oracle, keys[t][m], vals[t][m])
    oracle = oracle.astype(np.int32)

    with tempfile.TemporaryDirectory() as root:
        kv = ShardedKV(KVConfig(n_keys=R, cols=D), S, spmd, commit_every=3)
        kv.attach_journal(root)
        for t in range(T // 2):
            kv.tick(keys[t], vals[t])
        kv.snapshot()
        for t in range(T // 2, T):
            kv.tick(keys[t], vals[t])
        del kv  # crash: every device buffer gone

        kv2 = ShardedKV(KVConfig(n_keys=R, cols=D, partitioned=True),
                        2 * S, spmd, plan=serving_plan(2 * S, "all"),
                        commit_every=2)
        rep = kv2.recover(root)
        kv2.flush()
        assert np.array_equal(kv2.table(), oracle), \
            "recovered table != acknowledged history"
        print(f"  snapshot@{rep['snapshot_step']}, replayed "
              f"{rep['replayed_ticks']} journaled tick(s): table BITWISE "
              f"equal to the acknowledged update stream")


def main() -> None:
    if not ARGS.chaos:
        demo()
        return
    chaos_toy_sweeps(ARGS.quick)
    chaos_spec_audit()
    chaos_elastic(ARGS.quick)
    chaos_serving(ARGS.quick)
    chaos_real_model(ARGS.quick)
    print("CHAOS_SUITE_OK")


if __name__ == "__main__":
    main()
