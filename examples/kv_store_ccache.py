"""The paper's key-value store on the CCache engine + kernels (Section 3.3).

    PYTHONPATH=src python examples/kv_store_ccache.py

Eight "cores" (a vmapped named axis) increment random keys of a shared
table. Three layers of the repo cooperate:

  1. blocked engine  — per-core on-demand privatization with W ways,
     evict-merge + dirty-merge counters (the paper's Fig. 9 machinery)
  2. flexible merge  — cross-core reconciliation with software-defined
     merge functions: plain add, saturating add, complex multiply, and an
     approximate (update-dropping) merge — the §6.3 diversity demo
  3. cscatter kernel — the same computation as one TPU Pallas call
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocked, ccache
from repro.core import merge_functions as mf
from repro.kernels import ops, ref

N_CORES, KEYS, COLS, UPDATES = 8, 256, 4, 512


def main() -> None:
    key = jax.random.key(0)
    table = jnp.zeros((KEYS, COLS))
    rows = jax.random.randint(jax.random.key(1), (N_CORES, UPDATES), 0, KEYS)
    vals = jnp.abs(jax.random.normal(jax.random.key(2),
                                     (N_CORES, UPDATES, COLS)))

    # --- 1. per-core privatization through the blocked source buffer -----
    def core_fn(rows_c, vals_c):
        cache = blocked.init_cache(ways=8, block_rows=4, cols=COLS,
                                   dtype=table.dtype)
        cache, local = blocked.cop_scatter(cache, table, rows_c, vals_c,
                                           mf.ADD)
        cache, local = blocked.flush(cache, local, mf.ADD)
        # delta vs. the shared source copy, then the flexible tree merge
        merged = ccache.merge(ccache.CView(src=table, upd=local), table,
                              "cores", mf.ADD)
        return merged, cache.n_evict_merges, cache.n_flush_merges

    merged, evicts, flushes = jax.vmap(core_fn, axis_name="cores")(rows, vals)
    gold = table.at[rows.reshape(-1)].add(vals.reshape(-1, COLS))
    err = float(jnp.max(jnp.abs(merged[0] - gold)))
    print(f"[blocked+tree-merge] max err vs serialization: {err:.2e}")
    print(f"  evict-merges/core: {np.asarray(evicts).tolist()}")
    print(f"  flush-merges/core: {np.asarray(flushes).tolist()}")

    # --- 2. merge-function diversity (paper §6.3) ------------------------
    upds = jax.vmap(lambda r, v: jnp.zeros_like(table).at[r].add(v))(rows, vals)
    sat = jax.vmap(lambda u: ccache.reduce_update(u, "cores",
                                                  mf.saturating_add(3.0),
                                                  force_tree=True),
                   axis_name="cores")(upds)
    satm = mf.saturating_add(3.0).apply(table, sat[0])
    print(f"[saturating merge] table max = {float(satm.max()):.2f} (cap 3.0)")

    drop = mf.dropping_add(0.5)
    total = jax.vmap(lambda u: ccache.reduce_update(u, "cores", drop),
                     axis_name="cores")(upds)
    approx = drop.apply(table, total[0], key=jax.random.key(7))
    kept = float(jnp.sum(approx) / jnp.sum(gold))
    print(f"[approximate merge] kept {kept:.0%} of update mass "
          f"(50% drop target)")

    z = jnp.tile(jnp.asarray([[1.0, 0.2]]), (KEYS, 1))        # 1+0.2i
    factors = jnp.tile(jnp.asarray([[[1.0, 0.1]]]), (N_CORES, KEYS, 1))
    prod = jax.vmap(lambda f: ccache.reduce_update(f, "cores",
                                                   mf.COMPLEX_MUL),
                    axis_name="cores")(factors)
    zm = mf.COMPLEX_MUL.apply(z, prod[0])
    print(f"[complex-mul merge] z[0] = {float(zm[0,0]):.3f}"
          f"{float(zm[0,1]):+.3f}i  (= (1+0.2i)*(1+0.1i)^8)")

    # --- 3. the same scatter as one Pallas kernel call -------------------
    out = ops.commutative_scatter(table, rows.reshape(-1),
                                  vals.reshape(-1, COLS), kind="add",
                                  block_rows=32, chunk=128)
    err = float(jnp.max(jnp.abs(out - gold)))
    print(f"[cscatter kernel] max err vs serialization: {err:.2e}")


if __name__ == "__main__":
    main()
