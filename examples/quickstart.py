"""Quickstart: train a tiny LM with the CCache gradient pipeline on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: config -> model -> optimizer ->
soft-merge gradient accumulation -> train steps -> checkpoint -> serve a
few greedy tokens from the trained weights.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_smoke_config
from repro.data.pipeline import batch_at, data_config_for
from repro.launch.steps import make_train_step
from repro.models.module import split_params
from repro.models.registry import build_model
from repro.optim import adamw, warmup_cosine


def main() -> None:
    cfg = get_smoke_config("qwen1_5_0_5b")
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8,
                        kind="train")
    model = build_model(cfg)
    opt = adamw(warmup_cosine(3e-3, 10, 100))

    # microbatches=2: gradient accumulation runs as CCache soft-merge —
    # per-microbatch grads coalesce privately, one merge per step.
    step = jax.jit(make_train_step(model, cfg, opt, num_microbatches=2))

    params, _ = split_params(model.init(jax.random.key(0)))
    state = {"params": params, "opt": opt.init(params)}
    dcfg = data_config_for(cfg, shape, seed=0)

    print(f"model: {cfg.name}, params = "
          f"{sum(x.size for x in jax.tree.leaves(params)):,}")
    for i in range(40):
        batch = jax.tree.map(jnp.asarray, batch_at(dcfg, i))
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == 39:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(d, 40, state, extras={"next_step": 40})
        print("checkpointed to", path)
        restored, _ = ckpt.restore(d, state)

    # Serve a few tokens greedily from the trained weights.
    prompt = jnp.asarray(batch_at(dcfg, 99)["tokens"][:2, :16])
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, 24))(restored["params"],
                                              {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    decode = jax.jit(model.decode_step)
    for t in range(16, 23):
        logits, caches = decode(restored["params"], tok, caches,
                                jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    print("greedy continuation ids:", out)


if __name__ == "__main__":
    main()
