"""End-to-end training driver example (the (b) deliverable's driver).

Default runs the xLSTM-125M *smoke* config for a quick CPU demonstration;
pass ``--full`` to train the real 125M-parameter configuration for a few
hundred steps (hours on CPU; the intended target is a TPU host, where the
same flags apply with --mesh prod):

    PYTHONPATH=src python examples/train_e2e.py               # quick demo
    PYTHONPATH=src python examples/train_e2e.py --full --steps 300

This is a thin veneer over ``repro.launch.train`` — checkpointing, NaN
skip-batch, preemption save and resume all come from the runtime driver.
Interrupt it (Ctrl-C) and re-run: it resumes from the last commit.
"""

import sys

from repro.launch import train as train_cli


def main() -> None:
    args = sys.argv[1:]
    full = "--full" in args
    if full:
        args.remove("--full")
    defaults = ["--arch", "xlstm-125m",
                "--ckpt-dir", "/tmp/repro_train_e2e",
                "--ckpt-every", "25"]
    if not full:
        defaults += ["--smoke", "--steps", "60", "--batch", "8",
                     "--seq", "128"]
    else:
        defaults += ["--steps", "300", "--batch", "8", "--seq", "1024",
                     "--microbatches", "2"]
    sys.argv = [sys.argv[0]] + defaults + args
    train_cli.main()


if __name__ == "__main__":
    main()
