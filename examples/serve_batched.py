"""Batched serving with a KV cache: prefill once, decode greedily.

    PYTHONPATH=src python examples/serve_batched.py [--arch hymba-1-5b]

Uses the smoke config of any architecture (hybrid archs exercise the ring
caches + recurrent SSM state). See repro.launch.serve for the CLI with
production-mesh sharding.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.module import split_params
from repro.models.registry import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1-5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params, _ = split_params(model.init(jax.random.key(0)))
    cache_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal(
                (args.batch, model.enc_len(args.prompt_len), cfg.d_model)),
            cfg.param_dtype)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
    # donate the KV caches: decode_step(params, tok, caches, pos)
    # updates them in place instead of reallocating every token
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{(time.time() - t0) * 1e3:.0f}ms")

    seqs = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seqs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t1
    rate = (args.gen - 1) * args.batch / dt
    print(f"decode: {args.gen - 1} steps, {rate:.1f} tok/s "
          f"({dt / (args.gen - 1) * 1e3:.1f} ms/step)")
    out = np.stack([np.asarray(s) for s in seqs], 1)
    print("sample ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
